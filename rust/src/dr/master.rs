//! DRM — the Dynamic Repartitioning Master (§3, Figure 1).
//!
//! Integrated into the DDPS driver. At each decision point (micro-batch
//! boundary in Spark, checkpoint barrier in Flink, mid-map in batch jobs)
//! it merges the DRWs' local histograms, blends them with the recent past,
//! constructs a candidate partitioner, and issues a [`DrDecision`]. A
//! positive decision is an **epoch bump**: the DRM installs the candidate
//! into its [`EpochedPartitioner`] and hands the engine the resulting
//! [`EpochSwap`], from which the engine derives its state-migration plan
//! (decision → epoch bump → plan; see DESIGN.md "Epochs and the shared
//! ShuffleStage core").
//!
//! The decision point runs sequentially or sharded over pool workers
//! ([`DrMaster::decide_sharded`], backed by [`super::parallel`]); both
//! paths are the same deterministic computation, so decisions, epochs and
//! migration plans are bitwise-identical at any thread count, and the
//! measured cost of the step is returned in
//! [`DrDecision::decision_wall_s`]:
//!
//! ```
//! use dynrepart::dr::{DrConfig, DrMaster, PartitionerChoice};
//! use dynrepart::sketch::Histogram;
//!
//! // one local histogram per DRW, merged at the decision point
//! let locals = vec![
//!     Histogram::from_counts(&[(1, 600.0), (2, 100.0)], 1000.0, 8),
//!     Histogram::from_counts(&[(1, 300.0), (3, 200.0)], 1000.0, 8),
//! ];
//! let mut drm = DrMaster::new(DrConfig::forced(), PartitionerChoice::Kip, 4, 7);
//! let d = drm.decide(locals.clone()); // == decide_sharded(locals, 1)
//! assert!(d.repartitioned());
//! assert_eq!(d.epoch, 1);
//! assert_eq!(d.histogram.entries()[0].key, 1); // 900 of 2000 in the union
//!
//! // the sharded decision point reproduces it bitwise
//! let mut drm4 = DrMaster::new(DrConfig::forced(), PartitionerChoice::Kip, 4, 7);
//! let d4 = drm4.decide_sharded(locals, 4);
//! assert_eq!(d.epoch, d4.epoch);
//! assert_eq!(d.histogram.entries(), d4.histogram.entries());
//! let (p, p4) = (d.new_partitioner().unwrap(), d4.new_partitioner().unwrap());
//! assert!((0..1000u64).all(|k| p.partition(k) == p4.partition(k)));
//! assert!(d.decision_wall_s >= 0.0 && d4.decision_wall_s >= 0.0);
//! ```

use super::{parallel, DrConfig};
use crate::partitioner::{
    EpochSwap, EpochedPartitioner, GedikConfig, GedikPartitioner, GedikStrategy, Kip, KipConfig,
    Mixed, Partitioner, PartitionerEpoch, Uhp,
};
use crate::sketch::{Histogram, SketchConfig};
use crate::workload::Key;
use std::collections::VecDeque;
use std::sync::Arc;
use std::time::Instant;

/// Which partitioning function family DR maintains. KIP is the paper's
/// contribution; the others are the Fig 2/3 baselines, runnable inside the
/// full system for end-to-end ablations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PartitionerChoice {
    Kip,
    Gedik(GedikStrategy),
    Mixed,
    /// Static uniform hashing — never repartitions (the no-DR baseline).
    Uhp,
}

impl PartitionerChoice {
    pub fn name(&self) -> &'static str {
        match self {
            PartitionerChoice::Kip => "KIP",
            PartitionerChoice::Gedik(s) => s.name(),
            PartitionerChoice::Mixed => "Mixed",
            PartitionerChoice::Uhp => "Hash",
        }
    }
}

/// The partitioner state the DRM evolves. Concrete (not boxed) so updates
/// can use each family's own update rule.
#[derive(Debug, Clone)]
enum DynPartitioner {
    Kip(Kip),
    Gedik(GedikPartitioner),
    Mixed(Mixed),
    Uhp(Uhp),
}

impl DynPartitioner {
    fn as_dyn(&self) -> &dyn Partitioner {
        match self {
            DynPartitioner::Kip(p) => p,
            DynPartitioner::Gedik(p) => p,
            DynPartitioner::Mixed(p) => p,
            DynPartitioner::Uhp(p) => p,
        }
    }
}

/// Delegating impl so the concrete family can be installed into an
/// [`EpochedPartitioner`] (`Arc<dyn Partitioner>`) without re-boxing per
/// family at every swap site.
impl Partitioner for DynPartitioner {
    #[inline]
    fn partition(&self, key: Key) -> usize {
        self.as_dyn().partition(key)
    }

    fn n_partitions(&self) -> usize {
        self.as_dyn().n_partitions()
    }

    fn explicit_routes(&self) -> usize {
        self.as_dyn().explicit_routes()
    }

    fn tail_shares(&self) -> Vec<f64> {
        self.as_dyn().tail_shares()
    }

    fn flat_routes(&self) -> Option<crate::partitioner::FlatRoutes> {
        // delegate so DRM-installed epochs get the flat fast path too
        self.as_dyn().flat_routes()
    }
}

/// Outcome of a DRM decision point.
#[derive(Debug, Clone)]
pub struct DrDecision {
    /// The epoch transition, if the DRM repartitioned; `None` keeps the
    /// current function. The engine derives its migration plan from this.
    pub swap: Option<EpochSwap>,
    /// The epoch in force *after* this decision.
    pub epoch: u64,
    /// Estimated max load share under the current partitioner.
    pub current_max_share: f64,
    /// Planned max load share under the candidate.
    pub planned_max_share: f64,
    /// The merged histogram the decision was based on.
    pub histogram: Histogram,
    /// Measured wall-clock seconds the decision took — histogram
    /// tree-merge, blending with the past, candidate construction and the
    /// install. [`decision_point_sharded`] widens this to the full
    /// decision-point span (DRW harvests included). A *measurement*: it
    /// varies run to run and is the only [`DrDecision`] field that depends
    /// on the thread count.
    ///
    /// [`decision_point_sharded`]: crate::ddps::exec::decision_point_sharded
    pub decision_wall_s: f64,
}

impl DrDecision {
    /// Did this decision install a new partitioner?
    pub fn repartitioned(&self) -> bool {
        self.swap.is_some()
    }

    /// The newly installed routing snapshot, if any.
    pub fn new_partitioner(&self) -> Option<PartitionerEpoch> {
        self.swap.as_ref().map(|s| s.to.clone())
    }
}

/// An un-adopted decision: everything [`DrMaster::decide_sharded`] used
/// to compute *except* the install. The histogram work, candidate
/// construction and share estimates have already happened (and the DRM's
/// blending memory has advanced), but the epoch is untouched — a decider
/// rules on the proposal and the engine then calls [`DrMaster::commit`]
/// or [`DrMaster::decline`]. Declining never bumps the epoch.
#[derive(Debug, Clone)]
pub struct DecisionProposal {
    /// The constructed candidate, `None` when DR is disabled or the
    /// family is UHP (nothing to adopt).
    candidate: Option<DynPartitioner>,
    /// The DRM's own gate: `force_updates || planned < current × (1 -
    /// min_gain)`. [`DrMaster::decide_sharded`] commits exactly when this
    /// holds; deciders may only restrain further.
    pub worth_it: bool,
    /// Estimated max load share under the installed routing.
    pub current_max_share: f64,
    /// Estimated max load share under the candidate.
    pub planned_max_share: f64,
    /// The blended histogram the proposal was derived from.
    pub histogram: Histogram,
    /// Measured wall-clock seconds the proposal took (the only
    /// thread-count-dependent field, like [`DrDecision::decision_wall_s`]).
    pub decision_wall_s: f64,
}

impl DecisionProposal {
    /// Is there a candidate routing at all?
    pub fn has_candidate(&self) -> bool {
        self.candidate.is_some()
    }

    /// The candidate routing, for predicting what adopting it would move.
    pub fn candidate(&self) -> Option<&dyn Partitioner> {
        self.candidate.as_ref().map(|c| c.as_dyn())
    }

    /// Relative imbalance gain of the candidate over the installed
    /// routing.
    pub fn relative_gain(&self) -> f64 {
        if self.current_max_share > 0.0 {
            (self.current_max_share - self.planned_max_share) / self.current_max_share
        } else {
            0.0
        }
    }
}

#[derive(Debug, Clone)]
pub struct DrMaster {
    cfg: DrConfig,
    choice: PartitionerChoice,
    n_partitions: usize,
    /// The construction seed, retained so elasticity events
    /// ([`DrMaster::rescale`]) can rebuild the family at a new partition
    /// count from the same deterministic base.
    seed: u64,
    /// The concrete family state candidates are derived from. Always the
    /// same allocation the current epoch routes through (`epoched` holds a
    /// clone of this `Arc`), so the two views cannot diverge.
    current: Arc<DynPartitioner>,
    /// The versioned handle engines route through; every accepted decision
    /// installs `current` here and bumps the epoch.
    epoched: EpochedPartitioner,
    /// Record of past histograms (§3) blended into each decision.
    past: VecDeque<Histogram>,
    /// Sketch-bounding knobs (default: unbounded — exact path, bitwise).
    sketch: SketchConfig,
    updates_issued: u64,
    decisions_made: u64,
}

impl DrMaster {
    pub fn new(cfg: DrConfig, choice: PartitionerChoice, n_partitions: usize, seed: u64) -> Self {
        Self::with_sketch(cfg, choice, n_partitions, seed, SketchConfig::default())
    }

    /// [`DrMaster::new`] with sketch-bounding knobs: `size_boundary` caps
    /// the DRW counter capacity and the per-node size of the decision
    /// point's histogram tree-merge, and `take_top_k` caps how many
    /// entries each DRW harvest ships ([`DrMaster::ship_size`]). The
    /// default [`SketchConfig`] reproduces [`DrMaster::new`] bit-for-bit.
    pub fn with_sketch(
        cfg: DrConfig,
        choice: PartitionerChoice,
        n_partitions: usize,
        seed: u64,
        sketch: SketchConfig,
    ) -> Self {
        let kip_cfg = KipConfig {
            lambda: cfg.lambda,
            epsilon: cfg.epsilon,
            ..Default::default()
        };
        let current = match choice {
            PartitionerChoice::Kip => {
                DynPartitioner::Kip(Kip::initial(n_partitions, kip_cfg, seed))
            }
            PartitionerChoice::Gedik(s) => DynPartitioner::Gedik(GedikPartitioner::initial(
                s,
                n_partitions,
                GedikConfig::default(),
                seed,
            )),
            PartitionerChoice::Mixed => DynPartitioner::Mixed(Mixed::initial(n_partitions, seed)),
            PartitionerChoice::Uhp => DynPartitioner::Uhp(Uhp::with_seed(n_partitions, seed)),
        };
        let current = Arc::new(current);
        let epoched = EpochedPartitioner::new(current.clone());
        Self {
            cfg,
            choice,
            n_partitions,
            seed,
            current,
            epoched,
            past: VecDeque::new(),
            sketch,
            updates_issued: 0,
            decisions_made: 0,
        }
    }

    /// Rebuild the partitioner family over `new_n` partitions and install
    /// it as a new epoch — the DRM half of a scale-out/in event. The family
    /// is reconstructed from the stored seed (same deterministic base as
    /// construction) and, for decision continuity, immediately re-fitted to
    /// the blend of the recorded past histograms, so heavy keys isolated
    /// before the rescale stay isolated after it. The returned
    /// [`EpochSwap`] crosses partition counts; the engine derives the
    /// migration plan from it exactly as for an ordinary repartitioning.
    pub fn rescale(&mut self, new_n: usize) -> EpochSwap {
        assert!(new_n > 0, "rescale requires at least one partition");
        self.n_partitions = new_n;
        let kip_cfg = KipConfig {
            lambda: self.cfg.lambda,
            epsilon: self.cfg.epsilon,
            ..Default::default()
        };
        let hist = if self.past.is_empty() {
            None
        } else {
            let locals: Vec<Histogram> = self.past.iter().cloned().collect();
            Some(Histogram::merge(&locals, self.histogram_size()))
        };
        let candidate = match self.choice {
            PartitionerChoice::Kip => {
                let base = Kip::initial(new_n, kip_cfg, self.seed);
                DynPartitioner::Kip(match &hist {
                    Some(h) => base.updated(h),
                    None => base,
                })
            }
            PartitionerChoice::Gedik(s) => {
                let base = GedikPartitioner::initial(s, new_n, GedikConfig::default(), self.seed);
                DynPartitioner::Gedik(match &hist {
                    Some(h) => base.update(h),
                    None => base,
                })
            }
            PartitionerChoice::Mixed => {
                let base = Mixed::initial(new_n, self.seed);
                DynPartitioner::Mixed(match &hist {
                    Some(h) => base.update(h),
                    None => base,
                })
            }
            PartitionerChoice::Uhp => DynPartitioner::Uhp(Uhp::with_seed(new_n, self.seed)),
        };
        self.current = Arc::new(candidate);
        self.updates_issued += 1;
        self.epoched.install_resized(self.current.clone())
    }

    pub fn config(&self) -> &DrConfig {
        &self.cfg
    }

    pub fn sketch(&self) -> SketchConfig {
        self.sketch
    }

    pub fn choice(&self) -> PartitionerChoice {
        self.choice
    }

    /// Partition count the master currently routes over (changes only
    /// through [`DrMaster::rescale`]).
    pub fn n_partitions(&self) -> usize {
        self.n_partitions
    }

    pub fn histogram_size(&self) -> usize {
        self.cfg.lambda * self.n_partitions
    }

    /// Per-worker counter capacity the DRWs should be created with —
    /// capped at `sketch.size_boundary` when a boundary is set.
    pub fn worker_capacity(&self) -> usize {
        let cap = self.cfg.counter_capacity_factor * self.histogram_size();
        if self.sketch.size_boundary > 0 {
            cap.min(self.sketch.size_boundary)
        } else {
            cap
        }
    }

    /// How many entries each DRW harvest ships to this master — the
    /// `take` cut of the original system. Without a `take_top_k` knob
    /// this is the full global histogram size B = λN.
    pub fn ship_size(&self) -> usize {
        if self.sketch.take_top_k > 0 {
            self.histogram_size().min(self.sketch.take_top_k)
        } else {
            self.histogram_size()
        }
    }

    /// Snapshot of the currently installed routing epoch.
    pub fn handle(&self) -> PartitionerEpoch {
        self.epoched.current()
    }

    /// The current epoch number (0 until the first accepted update).
    pub fn epoch(&self) -> u64 {
        self.epoched.epoch()
    }

    pub fn updates_issued(&self) -> u64 {
        self.updates_issued
    }

    pub fn decisions_made(&self) -> u64 {
        self.decisions_made
    }

    /// Blend the incoming merged histogram with the recorded past ones.
    fn blended(&mut self, merged: Histogram) -> Histogram {
        self.past.push_back(merged);
        while self.past.len() > self.cfg.histogram_memory.max(1) {
            self.past.pop_front();
        }
        let locals: Vec<Histogram> = self.past.iter().cloned().collect();
        Histogram::merge(&locals, self.histogram_size())
    }

    /// Estimated max load share of `p` under `hist`: tracked heavy keys at
    /// their explicit/hashed locations plus the residual mass spread by the
    /// function's own tail routing (`tail_shares`) — the same model the
    /// partitioners plan with.
    fn max_share(p: &dyn Partitioner, hist: &Histogram) -> f64 {
        let residual = (1.0 - hist.heavy_mass()).max(0.0);
        let mut load: Vec<f64> = p.tail_shares().iter().map(|s| s * residual).collect();
        for e in hist.entries() {
            load[p.partition(e.key)] += e.freq;
        }
        load.iter().cloned().fold(0.0, f64::max)
    }

    /// The DRM decision point: merge worker histograms, maybe construct and
    /// install a new partitioner. This is the paper's central control loop,
    /// now phrased as decision → epoch bump → plan. Sequential shorthand
    /// for [`DrMaster::decide_sharded`] with one thread — the computation
    /// is the same deterministic tree, so the two agree bitwise.
    pub fn decide(&mut self, worker_histograms: Vec<Histogram>) -> DrDecision {
        self.decide_sharded(worker_histograms, 1)
    }

    /// [`DrMaster::decide`] with the decision point sharded over
    /// `num_threads` pool workers ([`super::parallel`]): the worker
    /// histograms merge in a parallel tree reduction whose shape depends
    /// only on their count, and the candidate's pure per-key preparation
    /// splits by key range while the order-sensitive greedy core runs
    /// unchanged. Decisions, epochs and migration plans are
    /// bitwise-identical at any `num_threads`; only the measured
    /// [`DrDecision::decision_wall_s`] varies.
    pub fn decide_sharded(
        &mut self,
        worker_histograms: Vec<Histogram>,
        num_threads: usize,
    ) -> DrDecision {
        let proposal = self.propose_sharded(worker_histograms, num_threads);
        if proposal.worth_it {
            self.commit(proposal)
        } else {
            self.decline(proposal)
        }
    }

    /// The proposal half of [`DrMaster::decide_sharded`]: merge the
    /// worker histograms, advance the blending memory and construct the
    /// best candidate — everything the decision point computes *except*
    /// the install, so the epoch is untouched. A decider then rules on
    /// the returned [`DecisionProposal`] and the caller either
    /// [`DrMaster::commit`]s or [`DrMaster::decline`]s it. Because no
    /// shared state swaps here, a pipelined engine can run this on its
    /// decision lane and leave the verdict to the epoch-swap barrier.
    pub fn propose_sharded(
        &mut self,
        worker_histograms: Vec<Histogram>,
        num_threads: usize,
    ) -> DecisionProposal {
        let wall_start = Instant::now();
        self.decisions_made += 1;
        let merged = parallel::merge_histograms_tree_bounded(
            worker_histograms,
            self.histogram_size(),
            self.sketch.size_boundary,
            num_threads,
        );
        let hist = self.blended(merged);

        let current_max = Self::max_share(self.current.as_dyn(), &hist);

        if !self.cfg.enabled || matches!(self.choice, PartitionerChoice::Uhp) {
            return DecisionProposal {
                candidate: None,
                worth_it: false,
                current_max_share: current_max,
                planned_max_share: current_max,
                histogram: hist,
                decision_wall_s: wall_start.elapsed().as_secs_f64(),
            };
        }

        // Construct the candidate with the family's own update rule (KIP
        // and Gedik with their pure preparation sharded; Mixed's bisection
        // has nothing pure to hoist and stays sequential).
        let candidate = match self.current.as_ref() {
            DynPartitioner::Kip(kip) => {
                DynPartitioner::Kip(parallel::kip_candidate(kip, &hist, num_threads))
            }
            DynPartitioner::Gedik(g) => {
                DynPartitioner::Gedik(parallel::gedik_candidate(g, &hist, num_threads))
            }
            DynPartitioner::Mixed(m) => DynPartitioner::Mixed(m.update(&hist)),
            DynPartitioner::Uhp(_) => unreachable!("handled above"),
        };
        let planned_max = Self::max_share(candidate.as_dyn(), &hist);

        // Decision: is the gain worth it? (Forced in Fig 3's methodology.)
        let worth_it = self.cfg.force_updates
            || planned_max < current_max * (1.0 - self.cfg.min_gain);

        DecisionProposal {
            candidate: Some(candidate),
            worth_it,
            current_max_share: current_max,
            planned_max_share: planned_max,
            histogram: hist,
            decision_wall_s: wall_start.elapsed().as_secs_f64(),
        }
    }

    /// Adopt a proposal: install the candidate as the new epoch. This is
    /// the install half of [`DrMaster::decide_sharded`] — callers gate it
    /// behind a decider verdict. Panics if the proposal carries no
    /// candidate (deciders never adopt those: `worth_it` is false).
    pub fn commit(&mut self, proposal: DecisionProposal) -> DrDecision {
        let wall_start = Instant::now();
        let candidate = proposal.candidate.expect("commit requires a candidate");
        self.current = Arc::new(candidate);
        let swap = self.epoched.install(self.current.clone());
        self.updates_issued += 1;
        DrDecision {
            epoch: swap.to_epoch(),
            swap: Some(swap),
            current_max_share: proposal.current_max_share,
            planned_max_share: proposal.planned_max_share,
            histogram: proposal.histogram,
            decision_wall_s: proposal.decision_wall_s + wall_start.elapsed().as_secs_f64(),
        }
    }

    /// Turn down a proposal: the epoch (and the routing engines see) is
    /// unchanged, and the candidate is dropped — the next barrier
    /// re-proposes from fresher histograms. The DRM's decision bookkeeping
    /// (blending memory, `decisions_made`) already advanced in
    /// [`DrMaster::propose_sharded`], so a declined barrier is
    /// indistinguishable from a not-worth-it one.
    pub fn decline(&self, proposal: DecisionProposal) -> DrDecision {
        DrDecision {
            swap: None,
            epoch: self.epoched.epoch(),
            current_max_share: proposal.current_max_share,
            planned_max_share: proposal.planned_max_share,
            histogram: proposal.histogram,
            decision_wall_s: proposal.decision_wall_s,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partitioner::partition_loads;
    use crate::util::load_imbalance;
    use crate::workload::{zipf::Zipf, Generator, Record};

    fn worker_hists(recs: &[Record], n_workers: usize, k: usize) -> Vec<Histogram> {
        let chunk = recs.len() / n_workers;
        (0..n_workers)
            .map(|w| Histogram::exact(&recs[w * chunk..(w + 1) * chunk], k))
            .collect()
    }

    #[test]
    fn disabled_dr_never_updates() {
        let mut drm = DrMaster::new(DrConfig::disabled(), PartitionerChoice::Kip, 8, 1);
        let mut z = Zipf::new(10_000, 1.2, 1);
        let recs = z.batch(100_000);
        let d = drm.decide(worker_hists(&recs, 4, drm.histogram_size()));
        assert!(d.swap.is_none());
        assert!(!d.repartitioned());
        assert_eq!(d.epoch, 0);
        assert_eq!(drm.updates_issued(), 0);
        assert_eq!(drm.epoch(), 0);
    }

    #[test]
    fn skew_triggers_update_and_improves() {
        let mut drm = DrMaster::new(DrConfig::default(), PartitionerChoice::Kip, 8, 2);
        let mut z = Zipf::new(50_000, 1.2, 2);
        let recs = z.batch(200_000);
        let before = drm.handle();
        let d = drm.decide(worker_hists(&recs, 4, drm.histogram_size()));
        assert!(d.repartitioned(), "skewed data must repartition");
        assert!(d.planned_max_share < d.current_max_share);
        let after = d.new_partitioner().unwrap();
        assert_eq!(after.epoch(), before.epoch() + 1);
        // measured imbalance must actually improve
        let kw: Vec<(Key, f64)> = {
            let mut m = std::collections::HashMap::new();
            for r in &recs {
                *m.entry(r.key).or_insert(0.0) += 1.0;
            }
            m.into_iter().collect()
        };
        let imb_before = load_imbalance(&partition_loads(before.as_dyn(), &kw));
        let imb_after = load_imbalance(&partition_loads(after.as_dyn(), &kw));
        assert!(imb_after < imb_before, "{imb_after} vs {imb_before}");
    }

    #[test]
    fn uniform_data_does_not_repartition() {
        let mut drm = DrMaster::new(DrConfig::default(), PartitionerChoice::Kip, 8, 3);
        let mut z = Zipf::new(100_000, 0.0, 3); // uniform
        let recs = z.batch(100_000);
        let d = drm.decide(worker_hists(&recs, 4, drm.histogram_size()));
        assert!(
            d.swap.is_none(),
            "uniform data repartitioned: cur={} planned={}",
            d.current_max_share,
            d.planned_max_share
        );
        assert_eq!(d.epoch, 0);
    }

    #[test]
    fn forced_updates_always_fire() {
        let mut drm = DrMaster::new(DrConfig::forced(), PartitionerChoice::Kip, 8, 4);
        let mut z = Zipf::new(100_000, 0.0, 4);
        let recs = z.batch(50_000);
        let d = drm.decide(worker_hists(&recs, 2, drm.histogram_size()));
        assert!(d.repartitioned());
        assert_eq!(drm.updates_issued(), 1);
        assert_eq!(drm.epoch(), 1);
    }

    #[test]
    fn all_baseline_choices_construct_and_update() {
        for choice in [
            PartitionerChoice::Kip,
            PartitionerChoice::Gedik(GedikStrategy::Scan),
            PartitionerChoice::Gedik(GedikStrategy::Readj),
            PartitionerChoice::Gedik(GedikStrategy::Redist),
            PartitionerChoice::Mixed,
        ] {
            let mut drm = DrMaster::new(DrConfig::forced(), choice, 6, 5);
            let mut z = Zipf::new(10_000, 1.3, 5);
            let recs = z.batch(50_000);
            let d = drm.decide(worker_hists(&recs, 3, drm.histogram_size()));
            assert!(d.repartitioned(), "{} failed", choice.name());
            let h = d.new_partitioner().unwrap();
            for k in 0..1000u64 {
                assert!(h.partition(k) < 6);
            }
        }
    }

    #[test]
    fn histogram_memory_smooths_drift() {
        // A one-batch blip should not dominate the blended histogram.
        let mut drm = DrMaster::new(
            DrConfig {
                histogram_memory: 3,
                force_updates: true,
                ..Default::default()
            },
            PartitionerChoice::Kip,
            4,
            6,
        );
        // two intervals dominated by key 1
        for _ in 0..2 {
            let h = Histogram::from_counts(&[(1, 900.0), (2, 100.0)], 1000.0, 8);
            drm.decide(vec![h]);
        }
        // blip: key 3 spikes for one interval with less data
        let blip = Histogram::from_counts(&[(3, 300.0), (1, 200.0)], 500.0, 8);
        let d = drm.decide(vec![blip]);
        // blended top key must still be 1 (2*900+200 vs 300)
        assert_eq!(d.histogram.entries()[0].key, 1);
    }

    #[test]
    fn handle_is_cheap_to_clone_and_consistent() {
        let drm = DrMaster::new(DrConfig::default(), PartitionerChoice::Kip, 16, 7);
        let h1 = drm.handle();
        let h2 = h1.clone();
        assert_eq!(h1.epoch(), h2.epoch());
        for k in 0..1000u64 {
            assert_eq!(h1.partition(k), h2.partition(k));
        }
    }

    #[test]
    fn epochs_bump_once_per_accepted_decision() {
        let mut drm = DrMaster::new(DrConfig::forced(), PartitionerChoice::Kip, 8, 8);
        let mut z = Zipf::new(20_000, 1.2, 8);
        for expect in 1..=4u64 {
            let recs = z.batch(40_000);
            let d = drm.decide(worker_hists(&recs, 2, drm.histogram_size()));
            let swap = d.swap.expect("forced update");
            assert_eq!(swap.from_epoch(), expect - 1);
            assert_eq!(swap.to_epoch(), expect);
            assert_eq!(d.epoch, expect);
            assert_eq!(drm.epoch(), expect);
        }
        assert_eq!(drm.updates_issued(), 4);
    }

    #[test]
    fn sharded_decide_is_bitwise_identical_for_every_family() {
        for choice in [
            PartitionerChoice::Kip,
            PartitionerChoice::Gedik(GedikStrategy::Scan),
            PartitionerChoice::Gedik(GedikStrategy::Readj),
            PartitionerChoice::Gedik(GedikStrategy::Redist),
            PartitionerChoice::Mixed,
            PartitionerChoice::Uhp,
        ] {
            let mut seq = DrMaster::new(DrConfig::forced(), choice, 8, 17);
            let mut par = DrMaster::new(DrConfig::forced(), choice, 8, 17);
            let mut z = Zipf::new(20_000, 1.2, 17);
            for round in 0..3 {
                let recs = z.batch(60_000);
                let hists = worker_hists(&recs, 5, seq.histogram_size());
                let ds = seq.decide(hists.clone());
                let dp = par.decide_sharded(hists, 4);
                let name = choice.name();
                assert_eq!(ds.repartitioned(), dp.repartitioned(), "{name} r{round}");
                assert_eq!(ds.epoch, dp.epoch, "{name} r{round}");
                assert_eq!(
                    ds.histogram.entries(),
                    dp.histogram.entries(),
                    "{name} r{round}: merged histograms diverged"
                );
                assert_eq!(
                    ds.current_max_share.to_bits(),
                    dp.current_max_share.to_bits(),
                    "{name} r{round}"
                );
                assert_eq!(
                    ds.planned_max_share.to_bits(),
                    dp.planned_max_share.to_bits(),
                    "{name} r{round}"
                );
                if let (Some(ss), Some(sp)) = (&ds.swap, &dp.swap) {
                    let plan_s = ss.plan(0..5_000u64);
                    let plan_p = sp.plan(0..5_000u64);
                    assert_eq!(plan_s, plan_p, "{name} r{round}: migration plans diverged");
                }
                assert!(ds.decision_wall_s >= 0.0 && dp.decision_wall_s >= 0.0);
            }
            assert_eq!(seq.epoch(), par.epoch(), "{}", choice.name());
        }
    }

    #[test]
    fn default_sketch_reproduces_plain_master_bitwise() {
        let mut plain = DrMaster::new(DrConfig::forced(), PartitionerChoice::Kip, 8, 21);
        let mut sk = DrMaster::with_sketch(
            DrConfig::forced(),
            PartitionerChoice::Kip,
            8,
            21,
            SketchConfig::default(),
        );
        assert_eq!(plain.worker_capacity(), sk.worker_capacity());
        assert_eq!(plain.ship_size(), sk.ship_size());
        assert_eq!(plain.ship_size(), plain.histogram_size());
        let mut z = Zipf::new(20_000, 1.2, 21);
        for _ in 0..3 {
            let recs = z.batch(60_000);
            let hists = worker_hists(&recs, 4, plain.histogram_size());
            let dp = plain.decide(hists.clone());
            let dsk = sk.decide(hists);
            assert_eq!(dp.repartitioned(), dsk.repartitioned());
            assert_eq!(dp.epoch, dsk.epoch);
            assert_eq!(dp.histogram.entries(), dsk.histogram.entries());
            assert_eq!(dp.current_max_share.to_bits(), dsk.current_max_share.to_bits());
            assert_eq!(dp.planned_max_share.to_bits(), dsk.planned_max_share.to_bits());
        }
    }

    #[test]
    fn sketch_knobs_cap_capacity_and_shipping() {
        let sketch = SketchConfig {
            compaction_interval: 1250,
            size_boundary: 12,
            take_top_k: 6,
        };
        let drm = DrMaster::with_sketch(DrConfig::default(), PartitionerChoice::Kip, 8, 22, sketch);
        assert_eq!(drm.sketch(), sketch);
        assert_eq!(drm.worker_capacity(), 12); // 4 * λN = 64, capped
        assert_eq!(drm.ship_size(), 6); // λN = 16, capped by take
    }

    #[test]
    fn bounded_decide_is_bitwise_identical_across_thread_counts() {
        let sketch = SketchConfig {
            compaction_interval: 0,
            size_boundary: 10,
            take_top_k: 8,
        };
        let mk =
            || DrMaster::with_sketch(DrConfig::forced(), PartitionerChoice::Kip, 8, 23, sketch);
        let mut seq = mk();
        let mut z = Zipf::new(20_000, 1.2, 23);
        let batches: Vec<_> = (0..3).map(|_| z.batch(60_000)).collect();
        let all_hists: Vec<Vec<Histogram>> =
            batches.iter().map(|r| worker_hists(r, 5, seq.ship_size())).collect();
        let seq_decisions: Vec<_> = all_hists.iter().map(|h| seq.decide(h.clone())).collect();
        for threads in [2usize, 4, 7] {
            let mut par = mk();
            for (ds, hists) in seq_decisions.iter().zip(&all_hists) {
                let dp = par.decide_sharded(hists.clone(), threads);
                assert_eq!(ds.repartitioned(), dp.repartitioned(), "{threads} threads");
                assert_eq!(ds.epoch, dp.epoch, "{threads} threads");
                assert_eq!(
                    ds.histogram.entries(),
                    dp.histogram.entries(),
                    "{threads} threads: bounded merge diverged"
                );
                assert_eq!(ds.planned_max_share.to_bits(), dp.planned_max_share.to_bits());
                if let (Some(ss), Some(sp)) = (&ds.swap, &dp.swap) {
                    assert_eq!(ss.plan(0..5_000u64), sp.plan(0..5_000u64), "{threads} threads");
                }
            }
            assert_eq!(seq.epoch(), par.epoch(), "{threads} threads");
        }
    }

    #[test]
    fn rescale_changes_partition_count_and_bumps_epoch() {
        for choice in [
            PartitionerChoice::Kip,
            PartitionerChoice::Gedik(GedikStrategy::Scan),
            PartitionerChoice::Mixed,
            PartitionerChoice::Uhp,
        ] {
            let mut drm = DrMaster::new(DrConfig::forced(), choice, 4, 31);
            let mut z = Zipf::new(20_000, 1.2, 31);
            let recs = z.batch(60_000);
            drm.decide(worker_hists(&recs, 4, drm.histogram_size()));
            let epoch_before = drm.epoch();
            let swap = drm.rescale(6);
            assert_eq!(swap.from.n_partitions(), 4, "{}", choice.name());
            assert_eq!(swap.to.n_partitions(), 6, "{}", choice.name());
            assert_eq!(swap.to_epoch(), epoch_before + 1);
            assert_eq!(drm.epoch(), epoch_before + 1);
            assert_eq!(drm.handle().n_partitions(), 6);
            assert_eq!(drm.histogram_size(), drm.config().lambda * 6);
            for k in 0..2000u64 {
                assert!(drm.handle().partition(k) < 6, "{}", choice.name());
            }
            for &(_, from, to) in &swap.plan(0..2000u64) {
                assert!(from < 4);
                assert!(to < 6);
            }
            // scale back in
            let swap2 = drm.rescale(2);
            assert_eq!(swap2.to.n_partitions(), 2);
            assert!(drm.handle().n_partitions() == 2);
        }
    }

    #[test]
    fn rescale_keeps_heavy_keys_isolated() {
        // decision continuity: after observing a heavy hitter, rescaling
        // must re-fit the candidate so the KIP routing table still tracks it
        let mut drm = DrMaster::new(DrConfig::forced(), PartitionerChoice::Kip, 4, 32);
        let h = Histogram::from_counts(&[(7, 900.0), (9, 60.0)], 1000.0, 8);
        drm.decide(vec![h]);
        drm.rescale(8);
        assert!(
            drm.handle().explicit_routes() > 0,
            "re-fitted KIP must carry explicit routes for observed heavy keys"
        );
    }

    #[test]
    fn rescale_is_deterministic() {
        let run = || {
            let mut drm = DrMaster::new(DrConfig::forced(), PartitionerChoice::Kip, 4, 33);
            let mut z = Zipf::new(10_000, 1.3, 33);
            let recs = z.batch(40_000);
            drm.decide(worker_hists(&recs, 2, drm.histogram_size()));
            drm.rescale(7);
            let recs2 = z.batch(40_000);
            let d = drm.decide(worker_hists(&recs2, 2, drm.histogram_size()));
            let routes: Vec<usize> = (0..3000u64).map(|k| drm.handle().partition(k)).collect();
            (d.epoch, d.planned_max_share.to_bits(), routes)
        };
        assert_eq!(run(), run());
    }

    #[test]
    fn cloned_master_evolves_identically() {
        let mut a = DrMaster::new(DrConfig::forced(), PartitionerChoice::Kip, 8, 34);
        let mut z = Zipf::new(10_000, 1.2, 34);
        let recs = z.batch(40_000);
        a.decide(worker_hists(&recs, 2, a.histogram_size()));
        let mut b = a.clone();
        let recs2 = z.batch(40_000);
        let hists = worker_hists(&recs2, 2, a.histogram_size());
        let da = a.decide(hists.clone());
        let db = b.decide(hists);
        assert_eq!(da.epoch, db.epoch);
        assert_eq!(da.histogram.entries(), db.histogram.entries());
        assert_eq!(da.planned_max_share.to_bits(), db.planned_max_share.to_bits());
        for k in 0..2000u64 {
            assert_eq!(a.handle().partition(k), b.handle().partition(k));
        }
    }

    #[test]
    fn swap_plan_agrees_with_routing_change() {
        let mut drm = DrMaster::new(DrConfig::forced(), PartitionerChoice::Kip, 8, 9);
        let mut z = Zipf::new(20_000, 1.4, 9);
        let recs = z.batch(100_000);
        let d = drm.decide(worker_hists(&recs, 4, drm.histogram_size()));
        let swap = d.swap.expect("forced update");
        for (k, from, to) in swap.plan(0..5000u64) {
            assert_eq!(from, swap.from.partition(k));
            assert_eq!(to, swap.to.partition(k));
            assert_ne!(from, to);
        }
    }
}
