//! Decider policies — *when* to repartition, not just how.
//!
//! The DRM's decision point always constructs the best candidate routing
//! it can ([`DrMaster::propose_sharded`]); a [`Decider`] then judges
//! whether adopting that candidate pays for itself. The original system
//! exposes this as a whole gating surface in `repartitioning.conf`
//! (`histogram-threshold`, `drift-boundary`/`drift-history-weight`,
//! `backoff-factor`, retentive weights, `significant-change`); until now
//! the reproduction ignored all of it and adopted eagerly, which is the
//! part of the paper's "negligible overhead" claim that restraint is
//! supposed to carry.
//!
//! Every policy judges from *virtual* inputs only — modeled load shares,
//! histogram mass, exact predicted migration weight, and the engine's
//! virtual cost constants. Measured wall clocks never enter a verdict,
//! so every policy is bitwise thread-count-invariant, exactly like the
//! sharded executor it gates (pinned in `tests/prop_decider.rs`).
//!
//! [`DrMaster::propose_sharded`]: super::DrMaster::propose_sharded

/// Which gating strategy an engine runs at its decision barrier.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DeciderPolicy {
    /// Always adopt a worthwhile candidate — bitwise-identical to the
    /// pre-decider behavior, and the oracle the other policies are
    /// measured against. The default.
    Naive,
    /// Adopt only when the histogram tracks enough heavy mass *and* the
    /// relative imbalance gain is significant.
    Threshold,
    /// Stickiness: adopt only when the relative gain outweighs the
    /// (exactly predicted) migration fraction, which is also capped.
    Retentive,
    /// EWMA drift detection plus a stage-time-vs-migration cost model,
    /// with a post-swap backoff cooldown.
    CostModel,
}

impl DeciderPolicy {
    /// Conf/env spelling of each policy (`decider.policy`,
    /// `DYNREPART_DECIDER`).
    pub const NAMES: [&'static str; 4] = ["naive", "threshold", "retentive", "cost-model"];

    pub fn name(self) -> &'static str {
        match self {
            DeciderPolicy::Naive => "naive",
            DeciderPolicy::Threshold => "threshold",
            DeciderPolicy::Retentive => "retentive",
            DeciderPolicy::CostModel => "cost-model",
        }
    }

    /// Strict parse of the conf/env spelling.
    pub fn parse(s: &str) -> Result<Self, String> {
        match s {
            "naive" => Ok(DeciderPolicy::Naive),
            "threshold" => Ok(DeciderPolicy::Threshold),
            "retentive" => Ok(DeciderPolicy::Retentive),
            "cost-model" => Ok(DeciderPolicy::CostModel),
            other => Err(format!(
                "unknown decider policy '{other}' (expected one of: {})",
                Self::NAMES.join(", ")
            )),
        }
    }

    /// Does this policy price state movement? Only then does the engine
    /// walk the live stores to predict the migration exactly; Naive and
    /// Threshold skip that work.
    pub fn prices_migration(self) -> bool {
        matches!(self, DeciderPolicy::Retentive | DeciderPolicy::CostModel)
    }
}

/// Gating knobs, embedded in [`DrConfig`](super::DrConfig) (and therefore
/// `Copy` like it). Each field is read by the policy named in its doc;
/// the others ignore it, so one config struct serves all four.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeciderConfig {
    pub policy: DeciderPolicy,
    /// Threshold: minimum fraction of total mass the blended histogram
    /// must track (heavy mass) before a swap is considered — below it the
    /// histogram is too thin to trust.
    pub histogram_threshold: f64,
    /// Threshold: minimum relative gain
    /// `(current_max - planned_max) / current_max` for a swap to count as
    /// a significant change.
    pub significant_change: f64,
    /// Retentive: hard cap on the predicted migration fraction of any
    /// adopted plan.
    pub max_migration: f64,
    /// Retentive: stickiness weight — the predicted migration fraction is
    /// scaled by this and subtracted from the relative gain; the swap is
    /// adopted only if the balance stays positive.
    pub retentive_weight: f64,
    /// CostModel: how far the current max share must rise above its EWMA
    /// before the workload counts as drifted.
    pub drift_boundary: f64,
    /// CostModel: EWMA history weight in `[0, 1)` — the fraction of the
    /// old average kept per observation (higher = slower to forget).
    pub drift_history_weight: f64,
    /// CostModel: cooldown after an adopted swap, counted in decision
    /// barriers; while it runs, every worthwhile proposal is deferred.
    pub backoff_factor: u64,
    /// CostModel: number of future intervals a stage-time gain is assumed
    /// to persist for when amortizing the migration cost.
    pub horizon: f64,
}

impl Default for DeciderConfig {
    fn default() -> Self {
        Self {
            policy: DeciderPolicy::Naive,
            histogram_threshold: 0.3,
            significant_change: 0.1,
            max_migration: 0.2,
            retentive_weight: 1.0,
            drift_boundary: 0.05,
            drift_history_weight: 0.5,
            backoff_factor: 2,
            horizon: 8.0,
        }
    }
}

impl DeciderConfig {
    /// Apply the `DYNREPART_DECIDER` (policy name) and
    /// `DYNREPART_DECIDER_BACKOFF` (cooldown barriers) environment knobs
    /// on top of this config. Unset/empty variables keep the current
    /// values; malformed ones abort with a message naming the variable,
    /// like every other `DYNREPART_*` knob.
    pub fn with_env(mut self) -> Self {
        if let Some(name) =
            crate::util::env::choice_from_env("DYNREPART_DECIDER", &DeciderPolicy::NAMES)
        {
            self.policy = DeciderPolicy::parse(name).expect("choice_from_env vetted the name");
        }
        if let Some(b) = crate::util::env::knob_from_env("DYNREPART_DECIDER_BACKOFF", 0) {
            self.backoff_factor = b as u64;
        }
        self
    }
}

/// What a policy rules on a proposal.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Verdict {
    /// Commit the candidate: install it and bump the epoch.
    Adopt,
    /// The candidate was worthwhile but the policy restrained it (gates
    /// unmet, cooldown running). The epoch stays; the candidate may be
    /// re-proposed — and re-judged — at the next barrier.
    Defer,
    /// Nothing to adopt: the candidate was not worthwhile to begin with
    /// (or DR is disabled).
    Reject,
}

/// Everything a policy may judge from, assembled by the engine at the
/// decision barrier. All fields are virtual/modeled quantities — shares
/// from [`DrMaster::propose_sharded`], exact predicted state movement,
/// and the engine's virtual cost constants — never measured wall time.
///
/// [`DrMaster::propose_sharded`]: super::DrMaster::propose_sharded
#[derive(Debug, Clone, Copy)]
pub struct ProposalStats {
    /// The DRM's own gate (`force_updates || planned < current × (1 -
    /// min_gain)`). Every policy rejects when this is false — restraint
    /// only ever *removes* swaps the pre-decider path would have made.
    pub worth_it: bool,
    /// Estimated max load share under the installed routing.
    pub current_max_share: f64,
    /// Estimated max load share under the candidate.
    pub planned_max_share: f64,
    /// Fraction of total mass the blended histogram tracks explicitly.
    pub heavy_mass: f64,
    /// State weight the candidate would move, summed over the live
    /// stores in exactly the order `apply_epoch_swap` walks them — so an
    /// adopted plan's measured `migrated_fraction` equals the prediction
    /// bitwise. Zero when the policy doesn't price migration.
    pub predicted_moved_weight: f64,
    /// `predicted_moved_weight` over the total live state weight.
    pub predicted_migration_fraction: f64,
    /// Reduce-side weight of the most recent completed stage — the
    /// CostModel's estimate of how much load a share improvement acts on.
    pub recent_load: f64,
    /// Virtual seconds of reduce work per unit weight (engine config).
    pub reduce_cost: f64,
    /// Virtual seconds to move one unit of state weight (engine config).
    pub migration_cost: f64,
}

impl ProposalStats {
    /// Relative imbalance gain of the candidate over the installed
    /// routing, in `[0, 1]` for any worthwhile proposal.
    pub fn relative_gain(&self) -> f64 {
        if self.current_max_share > 0.0 {
            (self.current_max_share - self.planned_max_share) / self.current_max_share
        } else {
            0.0
        }
    }
}

/// A repartitioning gate: rules on each [`ProposalStats`] in barrier
/// order. Implementations may keep state (EWMA history, cooldowns) —
/// which is why `judge` takes `&mut self` and why engine-resident
/// deciders are cloned into every `RecoveryPoint`.
pub trait Decider {
    fn name(&self) -> &'static str;
    fn judge(&mut self, stats: &ProposalStats) -> Verdict;
}

/// Always adopt a worthwhile candidate — the pre-decider behavior.
#[derive(Debug, Clone, Copy, Default)]
pub struct Naive;

impl Decider for Naive {
    fn name(&self) -> &'static str {
        "naive"
    }

    fn judge(&mut self, s: &ProposalStats) -> Verdict {
        if s.worth_it {
            Verdict::Adopt
        } else {
            Verdict::Reject
        }
    }
}

/// Histogram-threshold + significant-change gating.
#[derive(Debug, Clone, Copy)]
pub struct Threshold {
    pub cfg: DeciderConfig,
}

impl Decider for Threshold {
    fn name(&self) -> &'static str {
        "threshold"
    }

    fn judge(&mut self, s: &ProposalStats) -> Verdict {
        if !s.worth_it {
            return Verdict::Reject;
        }
        if s.heavy_mass >= self.cfg.histogram_threshold
            && s.relative_gain() >= self.cfg.significant_change
        {
            Verdict::Adopt
        } else {
            Verdict::Defer
        }
    }
}

/// Stickiness toward the installed routing: migration is priced against
/// the gain and hard-capped.
#[derive(Debug, Clone, Copy)]
pub struct Retentive {
    pub cfg: DeciderConfig,
}

impl Decider for Retentive {
    fn name(&self) -> &'static str {
        "retentive"
    }

    fn judge(&mut self, s: &ProposalStats) -> Verdict {
        if !s.worth_it {
            return Verdict::Reject;
        }
        let frac = s.predicted_migration_fraction;
        if frac > self.cfg.max_migration {
            return Verdict::Defer;
        }
        if s.relative_gain() - self.cfg.retentive_weight * frac > 0.0 {
            Verdict::Adopt
        } else {
            Verdict::Defer
        }
    }
}

/// EWMA drift detection + amortized cost model + post-swap backoff.
#[derive(Debug, Clone, Copy)]
pub struct CostModel {
    pub cfg: DeciderConfig,
    /// EWMA of the observed `current_max_share`, `None` before the first
    /// observation. Updated on *every* judged barrier (cooldown included)
    /// so the history stays warm.
    ewma: Option<f64>,
    /// Barriers left in the post-swap cooldown.
    cooldown: u64,
}

impl CostModel {
    pub fn new(cfg: DeciderConfig) -> Self {
        Self { cfg, ewma: None, cooldown: 0 }
    }
}

impl Decider for CostModel {
    fn name(&self) -> &'static str {
        "cost-model"
    }

    fn judge(&mut self, s: &ProposalStats) -> Verdict {
        // Drift is judged against the history *before* this observation.
        let prev = self.ewma;
        let x = s.current_max_share;
        let w = self.cfg.drift_history_weight;
        self.ewma = Some(match prev {
            Some(e) => w * e + (1.0 - w) * x,
            None => x,
        });
        if self.cooldown > 0 {
            self.cooldown -= 1;
            return if s.worth_it { Verdict::Defer } else { Verdict::Reject };
        }
        if !s.worth_it {
            return Verdict::Reject;
        }
        // No history yet means no ground to argue restraint from.
        let drifted = match prev {
            Some(e) => x - e > self.cfg.drift_boundary,
            None => true,
        };
        if !drifted {
            return Verdict::Defer;
        }
        // Predicted stage-time gain over the horizon vs modeled pause.
        let gain = self.cfg.horizon
            * (s.current_max_share - s.planned_max_share)
            * s.recent_load
            * s.reduce_cost;
        let cost = s.predicted_moved_weight * s.migration_cost;
        if gain > cost {
            self.cooldown = self.cfg.backoff_factor;
            Verdict::Adopt
        } else {
            Verdict::Defer
        }
    }
}

#[derive(Debug, Clone, Copy)]
enum Strategy {
    Naive(Naive),
    Threshold(Threshold),
    Retentive(Retentive),
    CostModel(CostModel),
}

/// The engine-resident decider: the configured strategy plus the
/// adopted/deferred tallies every report surfaces. Lives in `EngineCore`
/// and is captured wholesale (EWMA history, backoff counter, tallies) by
/// every `RecoveryPoint`, so a fail-restore mid-cooldown resumes the
/// gate bitwise — pinned in `tests/e2e_recovery.rs`.
#[derive(Debug, Clone, Copy)]
pub struct DeciderState {
    strategy: Strategy,
    adopted: u64,
    deferred: u64,
}

impl DeciderState {
    pub fn new(cfg: DeciderConfig) -> Self {
        let strategy = match cfg.policy {
            DeciderPolicy::Naive => Strategy::Naive(Naive),
            DeciderPolicy::Threshold => Strategy::Threshold(Threshold { cfg }),
            DeciderPolicy::Retentive => Strategy::Retentive(Retentive { cfg }),
            DeciderPolicy::CostModel => Strategy::CostModel(CostModel::new(cfg)),
        };
        Self { strategy, adopted: 0, deferred: 0 }
    }

    pub fn policy(&self) -> DeciderPolicy {
        match self.strategy {
            Strategy::Naive(_) => DeciderPolicy::Naive,
            Strategy::Threshold(_) => DeciderPolicy::Threshold,
            Strategy::Retentive(_) => DeciderPolicy::Retentive,
            Strategy::CostModel(_) => DeciderPolicy::CostModel,
        }
    }

    /// Swaps this decider adopted so far.
    pub fn adopted(&self) -> u64 {
        self.adopted
    }

    /// Worthwhile proposals this decider restrained so far.
    pub fn deferred(&self) -> u64 {
        self.deferred
    }

    /// Barriers left in the CostModel cooldown (0 for other policies).
    pub fn cooldown(&self) -> u64 {
        match self.strategy {
            Strategy::CostModel(cm) => cm.cooldown,
            _ => 0,
        }
    }

    /// The CostModel's EWMA of the current max share (`None` for other
    /// policies or before the first observation).
    pub fn ewma(&self) -> Option<f64> {
        match self.strategy {
            Strategy::CostModel(cm) => cm.ewma,
            _ => None,
        }
    }
}

impl Decider for DeciderState {
    fn name(&self) -> &'static str {
        self.policy().name()
    }

    fn judge(&mut self, stats: &ProposalStats) -> Verdict {
        let verdict = match &mut self.strategy {
            Strategy::Naive(d) => d.judge(stats),
            Strategy::Threshold(d) => d.judge(stats),
            Strategy::Retentive(d) => d.judge(stats),
            Strategy::CostModel(d) => d.judge(stats),
        };
        match verdict {
            Verdict::Adopt => self.adopted += 1,
            Verdict::Defer => self.deferred += 1,
            Verdict::Reject => {}
        }
        verdict
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn stats(worth_it: bool) -> ProposalStats {
        ProposalStats {
            worth_it,
            current_max_share: 0.4,
            planned_max_share: 0.2,
            heavy_mass: 0.6,
            predicted_moved_weight: 100.0,
            predicted_migration_fraction: 0.1,
            recent_load: 10_000.0,
            reduce_cost: 10e-6,
            migration_cost: 2e-6,
        }
    }

    #[test]
    fn policy_names_round_trip() {
        for name in DeciderPolicy::NAMES {
            assert_eq!(DeciderPolicy::parse(name).unwrap().name(), name);
        }
        assert!(DeciderPolicy::parse("eager").is_err());
    }

    #[test]
    fn naive_mirrors_worth_it() {
        let mut d = DeciderState::new(DeciderConfig::default());
        assert_eq!(d.judge(&stats(true)), Verdict::Adopt);
        assert_eq!(d.judge(&stats(false)), Verdict::Reject);
        assert_eq!(d.adopted(), 1);
        assert_eq!(d.deferred(), 0);
    }

    #[test]
    fn threshold_gates_on_mass_and_gain() {
        let cfg = DeciderConfig {
            policy: DeciderPolicy::Threshold,
            histogram_threshold: 0.5,
            significant_change: 0.1,
            ..Default::default()
        };
        let mut d = DeciderState::new(cfg);
        assert_eq!(d.judge(&stats(true)), Verdict::Adopt);
        let thin = ProposalStats { heavy_mass: 0.2, ..stats(true) };
        assert_eq!(d.judge(&thin), Verdict::Defer);
        let marginal = ProposalStats { planned_max_share: 0.39, ..stats(true) };
        assert_eq!(d.judge(&marginal), Verdict::Defer);
        assert_eq!(d.judge(&stats(false)), Verdict::Reject);
        assert_eq!((d.adopted(), d.deferred()), (1, 2));
    }

    #[test]
    fn retentive_caps_and_prices_migration() {
        let cfg = DeciderConfig {
            policy: DeciderPolicy::Retentive,
            max_migration: 0.2,
            retentive_weight: 1.0,
            ..Default::default()
        };
        let mut d = DeciderState::new(cfg);
        assert_eq!(d.judge(&stats(true)), Verdict::Adopt);
        let heavy = ProposalStats { predicted_migration_fraction: 0.3, ..stats(true) };
        assert_eq!(d.judge(&heavy), Verdict::Defer, "over the cap");
        // gain 0.5, weighted migration 0.15 → adopt; weight 10 → defer
        let sticky = DeciderConfig { retentive_weight: 10.0, ..cfg };
        let mut d2 = DeciderState::new(sticky);
        let frac = ProposalStats { predicted_migration_fraction: 0.15, ..stats(true) };
        assert_eq!(d2.judge(&frac), Verdict::Defer);
    }

    #[test]
    fn cost_model_backs_off_after_adoption() {
        let cfg = DeciderConfig {
            policy: DeciderPolicy::CostModel,
            backoff_factor: 2,
            drift_boundary: -1.0, // always "drifted" — isolate the backoff
            ..Default::default()
        };
        let mut d = DeciderState::new(cfg);
        assert_eq!(d.judge(&stats(true)), Verdict::Adopt);
        assert_eq!(d.cooldown(), 2);
        assert_eq!(d.judge(&stats(true)), Verdict::Defer);
        assert_eq!(d.judge(&stats(true)), Verdict::Defer);
        assert_eq!(d.cooldown(), 0);
        assert_eq!(d.judge(&stats(true)), Verdict::Adopt);
        assert_eq!((d.adopted(), d.deferred()), (2, 2));
    }

    #[test]
    fn cost_model_defers_without_drift_and_updates_history() {
        let cfg = DeciderConfig {
            policy: DeciderPolicy::CostModel,
            drift_boundary: 0.05,
            drift_history_weight: 0.5,
            backoff_factor: 0,
            ..Default::default()
        };
        let mut d = DeciderState::new(cfg);
        // First observation bootstraps the EWMA and may adopt.
        assert_eq!(d.judge(&stats(true)), Verdict::Adopt);
        assert_eq!(d.ewma(), Some(0.4));
        // Stationary shares: no drift, defer.
        assert_eq!(d.judge(&stats(true)), Verdict::Defer);
        // A spike beyond the boundary re-arms adoption.
        let spiked = ProposalStats { current_max_share: 0.8, ..stats(true) };
        assert_eq!(d.judge(&spiked), Verdict::Adopt);
    }

    #[test]
    fn cost_model_rejects_unaffordable_swaps() {
        let cfg = DeciderConfig {
            policy: DeciderPolicy::CostModel,
            drift_boundary: -1.0,
            horizon: 1.0,
            ..Default::default()
        };
        let mut d = DeciderState::new(cfg);
        // gain = 1.0 × 0.2 × 10000 × 10e-6 = 0.02 < cost = 1e7 × 2e-6 = 20
        let pricey = ProposalStats { predicted_moved_weight: 1e7, ..stats(true) };
        assert_eq!(d.judge(&pricey), Verdict::Defer);
        assert_eq!(d.cooldown(), 0, "deferred swaps must not arm the backoff");
    }
}
