//! **Dynamic Repartitioning** — the paper's system contribution (§3).
//!
//! The DR framework is pluggable into the DDPS engines in [`crate::ddps`]:
//!
//! - [`DrWorker`] (DRW) lives inside each DDPS worker and taps the keys
//!   flowing through the map/source side, feeding a low-memory
//!   [`FreqCounter`](crate::sketch::FreqCounter);
//! - [`DrMaster`] (DRM) is the central authority integrated into the
//!   driver: it merges worker-local histograms, keeps a record of past
//!   histograms ("to ensure that a partitioner construction is useful in
//!   the long run"), runs the partitioner update (KIP by default, any
//!   baseline for comparison), and decides *whether* the expected gain
//!   justifies the replay / state-migration cost.
//! - [`parallel`] shards the DRM decision point itself over the shared
//!   worker pool — parallel tree-merge of the DRW histograms and key-range
//!   preparation of the candidate construction — with decisions, epochs
//!   and migration plans bitwise-identical to the sequential path at any
//!   thread count (DESIGN.md "Sharded DRM decision point"). The measured
//!   cost of the step lands in the `decision_wall_s` report columns.

pub mod decider;
pub mod master;
pub mod parallel;
pub mod worker;

pub use decider::{
    Decider, DeciderConfig, DeciderPolicy, DeciderState, ProposalStats, Verdict,
};
pub use master::{DecisionProposal, DrDecision, DrMaster, PartitionerChoice};
pub use worker::DrWorker;

/// Configuration of the DR module (both DRM and DRW sides).
#[derive(Debug, Clone, Copy)]
pub struct DrConfig {
    /// Master switch — `false` reproduces the baseline system exactly.
    pub enabled: bool,
    /// DRW key-sampling probability on the map path (1.0 = observe all).
    /// The paper's overhead is "negligible" because the tap is a counter
    /// bump; we keep it configurable to measure the overhead curve.
    pub sample_rate: f64,
    /// Multiple of B = λN giving each worker-local counter capacity.
    pub counter_capacity_factor: usize,
    /// Histogram scale factor λ (global top-B with B = λN).
    pub lambda: usize,
    /// KIP slack ε (Algorithm 1).
    pub epsilon: f64,
    /// How many past histograms to blend when updating (drift smoothing).
    pub histogram_memory: usize,
    /// Minimum relative improvement of the planned max load before a
    /// repartitioning is worth its migration cost (decision threshold).
    pub min_gain: f64,
    /// Force an update at every opportunity (Fig 3's methodology:
    /// "We forced a partitioner update on each batch").
    pub force_updates: bool,
    /// Gating policy ruling on each worthwhile proposal at the engines'
    /// decision barrier ([`decider`]). The default `Naive` policy adopts
    /// every worthwhile candidate — the pre-decider behavior, bitwise.
    pub decider: DeciderConfig,
}

impl Default for DrConfig {
    fn default() -> Self {
        Self {
            enabled: true,
            sample_rate: 1.0,
            counter_capacity_factor: 4,
            lambda: 2,
            epsilon: 0.01,
            histogram_memory: 3,
            min_gain: 0.05,
            force_updates: false,
            decider: DeciderConfig::default(),
        }
    }
}

impl DrConfig {
    pub fn disabled() -> Self {
        Self {
            enabled: false,
            ..Default::default()
        }
    }

    pub fn forced() -> Self {
        Self {
            force_updates: true,
            ..Default::default()
        }
    }
}
