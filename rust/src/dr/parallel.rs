//! The sharded DRM decision point (see DESIGN.md "Sharded DRM decision
//! point").
//!
//! PR 2 parallelized the `ShuffleStage` executor but left the DRM a
//! single-threaded serial region between parallel shards. The paper's
//! "negligible overhead" claim needs the decision point — merge DRW
//! histograms, blend with the recent past, construct a candidate
//! partitioner — to cost little compared to the stage it steers even as
//! worker counts grow (AutoFlow and Fang et al. both stress that the
//! rebalancing controller must scale with the workers or it becomes the
//! new bottleneck). This module shards the two heavy steps over the same
//! persistent worker pool the stage executor dispatches to
//! ([`ddps::exec::pool`](crate::ddps::exec::pool) — parked threads, no
//! per-decision spawns):
//!
//! - **Histogram merge** ([`merge_histograms_tree`]): the DRW locals are
//!   merged in a pairwise *tree reduction* through the existing
//!   [`MergeableSketch::merge_from`] contract. The tree shape — always
//!   merge adjacent nodes `(2i, 2i+1)`, level by level — is a pure
//!   function of the local count and **never of the thread count**; a
//!   level's pair-merges are independent, so they are distributed over
//!   pool tasks (each owning a disjoint, pair-aligned `&mut` slice)
//!   without changing a single float operation. `num_threads = 1` runs
//!   the same tree serially: results are bitwise-identical at any thread
//!   count by construction.
//! - **Candidate construction** ([`kip_candidate`], [`gedik_candidate`]):
//!   the greedy cores of KIP's Algorithm 1 and Gedik's strategies are
//!   order-sensitive (every placement reads the load vector the previous
//!   placements wrote), so they are *not* split. What is split — by key
//!   range — are the pure per-key location reads that feed them
//!   (line-4/line-7 lookups for KIP, current-location reads for
//!   Readj/Scan), while KIP's host→partition bucketing (the tail
//!   bin-packing input of lines 11–15) rides the submitting thread's
//!   task concurrent with the heavy-key reads — at most `num_threads`
//!   pool threads are ever busy, the same budget the stage executor
//!   honours. The cores then consume the precomputed tables
//!   through [`Kip::update_with_locations`] /
//!   [`GedikPartitioner::update_with_locations`] in the exact sequential
//!   operation order — decisions, epochs and migration plans are
//!   bitwise-identical to the sequential path. ([`Mixed`]'s bisection
//!   loop does per-entry `argmin`s only — nothing pure to hoist — and
//!   [`Uhp`](crate::partitioner::Uhp) never repartitions; both stay
//!   sequential.)
//!
//! [`DrMaster::decide_sharded`](super::DrMaster::decide_sharded) drives
//! both pieces;
//! [`decision_point_sharded`](crate::ddps::exec::decision_point_sharded)
//! adds the sharded DRW harvests in front and the engines thread
//! [`EngineConfig::num_threads`](crate::ddps::EngineConfig::num_threads)
//! through. The measured cost of the whole step lands in the
//! `decision_wall_s` report columns (EXPERIMENTS.md "Decision latency";
//! `cargo bench --bench micro_drm_decision`).
//!
//! ```
//! use dynrepart::dr::parallel::merge_histograms_tree;
//! use dynrepart::sketch::Histogram;
//!
//! // six DRW locals; key 99 is moderate in each but heavy in the union
//! let locals: Vec<Histogram> = (0u64..6)
//!     .map(|w| Histogram::from_counts(&[(w, 10.0 + w as f64), (99, 25.0)], 100.0, 8))
//!     .collect();
//! let seq = merge_histograms_tree(locals.clone(), 4, 1);
//! let par = merge_histograms_tree(locals, 4, 4);
//! assert_eq!(seq.entries(), par.entries()); // bitwise-identical at any thread count
//! assert_eq!(seq.entries()[0].key, 99); // 6 × 25 / 600 = 25% of the union
//! ```
//!
//! [`MergeableSketch::merge_from`]: crate::sketch::MergeableSketch::merge_from
//! [`Mixed`]: crate::partitioner::Mixed

use crate::ddps::exec::pool::{SharedSlice, WorkerPool};
use crate::partitioner::{GedikPartitioner, GedikStrategy, Kip, Partitioner};
use crate::sketch::{Histogram, MergeableSketch};
use crate::workload::Key;
use std::sync::Mutex;

/// Merge worker-local histograms into the global top-`k` through a
/// deterministic pairwise tree reduction over
/// [`MergeableSketch::merge_from`](crate::sketch::MergeableSketch::merge_from).
///
/// The reduction pairs adjacent nodes `(2i, 2i+1)` level by level until
/// one histogram remains, then re-bounds it with
/// [`Histogram::truncate_top`]. The tree shape depends only on
/// `locals.len()`; `num_threads` only chooses how many pool workers a
/// level's independent pair-merges are spread over, so the result is
/// bitwise-identical at any thread count (`1` runs the same tree
/// serially). Ranking of tied counts is stable by key — guaranteed by
/// `merge_from` itself — so no fold shape can reorder heavy hitters.
pub fn merge_histograms_tree(locals: Vec<Histogram>, k: usize, num_threads: usize) -> Histogram {
    merge_histograms_tree_bounded(locals, k, 0, num_threads)
}

/// [`merge_histograms_tree`] with a mid-fold size boundary: after every
/// pair-merge the merged node is re-bounded to its top `bound` entries
/// (`bound = 0` keeps every intermediate node exact — the unbounded path
/// above, bit-for-bit). This keeps the peak footprint of the fold at
/// O(`bound`) per node instead of O(union of keys).
///
/// The bounded fold is still deterministic at any thread count: the tree
/// shape is unchanged (a pure function of `locals.len()`), each bounded
/// pair-merge is a pure function of its two inputs, and the truncation
/// ranks exactly as `merge_from` sorted — on accumulated absolute counts
/// with ties broken by ascending key — so which worker runs a pair still
/// cannot affect its value. (The bounded result may differ from the
/// unbounded one — truncation drops tail mass — but it differs
/// *identically* across thread counts and fold orders.)
pub fn merge_histograms_tree_bounded(
    locals: Vec<Histogram>,
    k: usize,
    bound: usize,
    num_threads: usize,
) -> Histogram {
    let mut nodes = locals;
    if nodes.is_empty() {
        return Histogram::empty();
    }
    while nodes.len() > 1 {
        merge_adjacent_pairs(&mut nodes, bound, num_threads);
        // Every pair's merge landed in its left (even-index) node; an odd
        // trailing node is also at an even index and carries up a level.
        nodes = nodes.into_iter().step_by(2).collect();
    }
    let mut merged = nodes.pop().expect("non-empty");
    merged.truncate_top(k);
    merged
}

/// One tree level: `nodes[2i] ← merge(nodes[2i], nodes[2i+1])` for every
/// adjacent pair, the pair-merges spread over up to `num_threads` pool
/// tasks on disjoint pair-aligned slices. When `bound > 0` each merged
/// node is truncated back to `bound` entries — `merge_from` leaves
/// entries count-sorted with key tie-breaks, so the truncation is a
/// deterministic suffix drop. Which worker computes a pair cannot affect
/// its value, so every thread count produces identical level results.
fn merge_adjacent_pairs(nodes: &mut [Histogram], bound: usize, num_threads: usize) {
    let pairs = nodes.len() / 2;
    if pairs == 0 {
        return;
    }
    // `move` so the closure captures `bound` by value and stays `Copy` —
    // each pool task below takes its own copy.
    let merge_pair = move |pair: &mut [Histogram]| {
        if let [left, right] = pair {
            left.merge_from(right);
            if bound > 0 {
                left.truncate_top(bound);
            }
        }
    };
    let workers = num_threads.max(1).min(pairs);
    if workers <= 1 {
        for pair in nodes.chunks_mut(2) {
            merge_pair(pair);
        }
        return;
    }
    let pair_chunk = pairs.div_ceil(workers);
    let n_tasks = pairs.div_ceil(pair_chunk);
    let pool = WorkerPool::for_threads(num_threads);
    // Restrict to the paired prefix: an odd trailing node needs no merge,
    // so it never gets (or wastes) a task.
    let shared = SharedSlice::new(&mut nodes[..pairs * 2]);
    pool.run(n_tasks, &|t| {
        let start = t * pair_chunk * 2;
        let end = (start + pair_chunk * 2).min(pairs * 2);
        // Safety: tasks own disjoint pair-aligned sub-slices.
        let slice = unsafe { shared.slice(start..end) };
        for pair in slice.chunks_mut(2) {
            merge_pair(pair);
        }
    });
}

/// Partition of every key in `keys` under `p`, computed over contiguous
/// key-range chunks on up to `num_threads` pool tasks (`partition` is
/// pure, so the output — in input order — is identical at any thread
/// count).
pub fn partitions_of(p: &dyn Partitioner, keys: &[Key], num_threads: usize) -> Vec<u32> {
    let mut out = vec![0u32; keys.len()];
    if num_threads <= 1 || keys.len() < 2 {
        for (o, &k) in out.iter_mut().zip(keys) {
            *o = p.partition(k) as u32;
        }
        return out;
    }
    let chunk = keys.len().div_ceil(num_threads).max(1);
    let n_tasks = keys.len().div_ceil(chunk);
    let pool = WorkerPool::for_threads(num_threads);
    let out_sh = SharedSlice::new(&mut out);
    pool.run(n_tasks, &|t| {
        let start = t * chunk;
        let end = (start + chunk).min(keys.len());
        // Safety: tasks own disjoint contiguous output ranges.
        let os = unsafe { out_sh.slice(start..end) };
        for (o, &k) in os.iter_mut().zip(&keys[start..end]) {
            *o = p.partition(k) as u32;
        }
    });
    out
}

/// KIP candidate construction with the pure preparation sharded: the
/// keys split into `num_threads` contiguous ranges, each pool task
/// reading both the line-4 (previous) and line-7 (hash) locations for
/// its range. The submitting thread takes the first range itself (task
/// 0), after bucketing hosts by partition for lines 11–15's tail
/// bin-packing — so at most `num_threads` threads are ever busy, the
/// same budget the stage executor honours. The greedy core runs
/// unchanged via [`Kip::update_with_locations`], so the result is
/// bitwise-identical to [`Kip::updated`] at any `num_threads`.
pub fn kip_candidate(kip: &Kip, hist: &Histogram, num_threads: usize) -> Kip {
    if num_threads <= 1 || hist.len() < 2 {
        return kip.updated(hist);
    }
    let cfg = kip.config();
    let hash = kip.weighted_hash();
    let keys: Vec<Key> = hist.entries().iter().map(|e| e.key).collect();
    let mut prev_locs = vec![0u32; keys.len()];
    let mut hash_locs = vec![0u32; keys.len()];
    let chunk = keys.len().div_ceil(num_threads).max(1);
    let n_tasks = keys.len().div_ceil(chunk);
    let fill = |ks: &[Key], ps: &mut [u32], hs: &mut [u32]| {
        for ((&k, p), h) in ks.iter().zip(ps.iter_mut()).zip(hs.iter_mut()) {
            *p = kip.partition(k) as u32;
            *h = hash.partition(k) as u32;
        }
    };
    let pool = WorkerPool::for_threads(num_threads);
    let ps_sh = SharedSlice::new(&mut prev_locs);
    let hs_sh = SharedSlice::new(&mut hash_locs);
    let hosts_slot = Mutex::new(Vec::new());
    let keys_ref = &keys[..];
    pool.run(n_tasks, &|t| {
        // Tail side rides task 0 — the submitting thread — concurrent
        // with the other tasks' heavy-key reads.
        if t == 0 {
            *hosts_slot.lock().expect("hosts slot") = hash.hosts_by_partition();
        }
        let start = t * chunk;
        let end = (start + chunk).min(keys_ref.len());
        // Safety: tasks own disjoint contiguous ranges of both tables.
        let ps = unsafe { ps_sh.slice(start..end) };
        let hs = unsafe { hs_sh.slice(start..end) };
        fill(&keys_ref[start..end], ps, hs);
    });
    let hosts_in = hosts_slot.into_inner().expect("hosts slot");
    Kip::update_with_locations(&prev_locs, &hash_locs, hosts_in, hash, hist, cfg)
}

/// Gedik candidate construction with the per-key current-location reads
/// sharded by key range; the strategy's greedy core runs unchanged via
/// [`GedikPartitioner::update_with_locations`], so the result is
/// bitwise-identical to [`GedikPartitioner::update`] at any
/// `num_threads`. Redist never reads current locations, so it has no
/// parallel preparation and falls through to the sequential update.
pub fn gedik_candidate(
    g: &GedikPartitioner,
    hist: &Histogram,
    num_threads: usize,
) -> GedikPartitioner {
    if num_threads <= 1 || hist.len() < 2 || matches!(g.strategy(), GedikStrategy::Redist) {
        return g.update(hist);
    }
    let keys: Vec<Key> = hist.entries().iter().map(|e| e.key).collect();
    let cur_locs = partitions_of(g, &keys, num_threads);
    g.update_with_locations(hist, &cur_locs)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::partitioner::{GedikConfig, KipConfig, Uhp, WeightedHash};
    use crate::workload::{zipf::Zipf, Generator};

    fn worker_locals(n_locals: usize, n_records: usize, exp: f64, seed: u64) -> Vec<Histogram> {
        let mut z = Zipf::new(20_000, exp, seed);
        let recs = z.batch(n_records);
        let per = recs.len().div_ceil(n_locals).max(1);
        recs.chunks(per).map(|c| Histogram::exact(c, 32)).collect()
    }

    #[test]
    fn tree_merge_identical_at_any_thread_count() {
        for n_locals in [1usize, 2, 3, 7, 8, 13] {
            let locals = worker_locals(n_locals, 60_000, 1.2, n_locals as u64);
            let seq = merge_histograms_tree(locals.clone(), 16, 1);
            for threads in [2usize, 3, 4, 8] {
                let par = merge_histograms_tree(locals.clone(), 16, threads);
                assert_eq!(
                    seq.entries(),
                    par.entries(),
                    "{n_locals} locals, {threads} threads: tree merge diverged"
                );
                assert_eq!(seq.total_weight().to_bits(), par.total_weight().to_bits());
            }
        }
    }

    #[test]
    fn tree_merge_finds_union_heavy_key_and_conserves_weight() {
        // key 9 moderate in each local, heavy in the union (the same
        // scenario sketch::merge_tests pins for the pairwise fold)
        let locals: Vec<Histogram> = (0..4u64)
            .map(|w| {
                Histogram::from_counts(
                    &[(9, 300.0), ((w + 1) * 1000, 400.0), ((w + 1) * 2000, 300.0)],
                    1000.0,
                    8,
                )
            })
            .collect();
        let m = merge_histograms_tree(locals, 8, 4);
        assert_eq!(m.entries()[0].key, 9);
        assert!((m.entries()[0].freq - 0.3).abs() < 1e-9);
        assert!((m.total_weight() - 4000.0).abs() < 1e-9);
    }

    #[test]
    fn bounded_tree_merge_identical_at_any_thread_count() {
        for n_locals in [1usize, 2, 3, 7, 8, 13] {
            let locals = worker_locals(n_locals, 60_000, 1.2, n_locals as u64);
            for bound in [4usize, 16, 64] {
                let seq = merge_histograms_tree_bounded(locals.clone(), 16, bound, 1);
                assert!(seq.len() <= 16);
                for threads in [2usize, 3, 4, 8] {
                    let par = merge_histograms_tree_bounded(locals.clone(), 16, bound, threads);
                    assert_eq!(
                        seq.entries(),
                        par.entries(),
                        "{n_locals} locals, bound {bound}, {threads} threads: diverged"
                    );
                    assert_eq!(seq.total_weight().to_bits(), par.total_weight().to_bits());
                }
            }
        }
    }

    #[test]
    fn bounded_tree_merge_caps_every_intermediate_node() {
        // With bound B, no node the fold produces can exceed B entries, so
        // the final result (before the top-k cut) is ≤ B as well: ask for
        // a huge k and check the boundary is what limits the output.
        let locals = worker_locals(9, 60_000, 0.8, 3);
        for bound in [2usize, 8, 32] {
            let m = merge_histograms_tree_bounded(locals.clone(), usize::MAX, bound, 4);
            assert!(m.len() <= bound, "bound {bound}: {} entries", m.len());
        }
    }

    #[test]
    fn bound_zero_is_bitwise_exact() {
        let locals = worker_locals(7, 60_000, 1.1, 9);
        let exact = merge_histograms_tree(locals.clone(), 16, 4);
        let bounded = merge_histograms_tree_bounded(locals, 16, 0, 4);
        assert_eq!(exact.entries(), bounded.entries());
        assert_eq!(exact.total_weight().to_bits(), bounded.total_weight().to_bits());
    }

    #[test]
    fn tree_merge_truncates_to_k() {
        let locals = worker_locals(6, 30_000, 1.0, 5);
        let m = merge_histograms_tree(locals, 4, 3);
        assert!(m.len() <= 4);
    }

    #[test]
    fn tree_merge_empty_inputs_are_safe() {
        assert!(merge_histograms_tree(Vec::new(), 8, 4).is_empty());
        let empties = vec![Histogram::empty(); 5];
        assert!(merge_histograms_tree(empties, 8, 4).is_empty());
    }

    #[test]
    fn partitions_of_matches_sequential() {
        let p = Uhp::with_seed(11, 3);
        let keys: Vec<Key> = (0..10_007u64).collect();
        let seq = partitions_of(&p, &keys, 1);
        for threads in [2usize, 3, 8] {
            assert_eq!(partitions_of(&p, &keys, threads), seq, "{threads} threads");
        }
        assert!(partitions_of(&p, &[], 4).is_empty());
    }

    #[test]
    fn kip_candidate_bitwise_matches_sequential_update() {
        let n = 12;
        let mut z = Zipf::new(50_000, 1.1, 7);
        let recs = z.batch(200_000);
        let cfg = KipConfig::default();
        let hist = Histogram::exact(&recs, cfg.histogram_size(n));
        let kip0 = Kip::update(
            &Uhp::new(n),
            &WeightedHash::with_default_hosts(n, 9),
            &hist,
            cfg,
        );
        let seq = kip0.updated(&hist);
        for threads in [2usize, 4, 7] {
            let par = kip_candidate(&kip0, &hist, threads);
            assert_eq!(
                seq.weighted_hash(),
                par.weighted_hash(),
                "{threads} threads: host maps diverged"
            );
            assert_eq!(seq.explicit_routes(), par.explicit_routes());
            for e in hist.entries() {
                assert_eq!(
                    seq.explicit_table().get(&e.key),
                    par.explicit_table().get(&e.key),
                    "{threads} threads: explicit route for key {} diverged",
                    e.key
                );
            }
            for k in 0..20_000u64 {
                assert_eq!(seq.partition(k), par.partition(k), "{threads} threads, key {k}");
            }
        }
    }

    #[test]
    fn gedik_candidate_bitwise_matches_sequential_update() {
        for strategy in [GedikStrategy::Scan, GedikStrategy::Readj, GedikStrategy::Redist] {
            let mut z = Zipf::new(30_000, 1.0, 11);
            let recs = z.batch(150_000);
            let hist = Histogram::exact(&recs, 24);
            let g0 = GedikPartitioner::initial(strategy, 12, GedikConfig::default(), 4);
            // second-generation update so current locations mix explicit
            // routes and ring lookups
            let g1 = g0.update(&hist);
            let mut z2 = Zipf::new(30_000, 1.0, 12);
            let hist2 = Histogram::exact(&z2.batch(150_000), 24);
            let seq = g1.update(&hist2);
            for threads in [2usize, 4, 7] {
                let par = gedik_candidate(&g1, &hist2, threads);
                assert_eq!(seq.explicit_routes(), par.explicit_routes(), "{strategy:?}");
                for k in 0..20_000u64 {
                    assert_eq!(
                        seq.partition(k),
                        par.partition(k),
                        "{strategy:?}, {threads} threads, key {k}"
                    );
                }
            }
        }
    }
}
